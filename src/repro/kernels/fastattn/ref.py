"""Pure-jnp oracles for the FastAttention kernel.

``standard_attention``  -- the paper's baseline: naive Softmax(QK^T/sqrt(d))V
                           with a materialized dense mask (no fusion, no
                           online softmax).
``flash_reference``     -- chunked online-softmax attention with the same
                           algorithmic structure (and numerics) as the Pallas
                           kernel.  Differentiable; also serves as the
                           model-side implementation for CPU dry-runs.

Both take (B, H, Sq, D) queries and (B, Hkv, Skv, D) keys/values with
Hq % Hkv == 0 (GQA) and support causal masks, sliding windows, logit
softcap, a global q-position offset (decode / chunked prefill) and KV
padding lengths.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import tiling_mask as tm

NEG_INF = -1e30


def _expand_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, h, s, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, h, n_rep, s, d)).reshape(
        b, h * n_rep, s, d)


def _apply_softcap(s: jax.Array, softcap: Optional[float]) -> jax.Array:
    if softcap is None:
        return s
    return softcap * jnp.tanh(s / softcap)


def standard_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True,
                       window: Optional[int] = None,
                       softcap: Optional[float] = None,
                       scale: Optional[float] = None,
                       q_offset: int = 0,
                       kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Naive attention with a fully materialized (Sq, Skv) mask."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    k = _expand_kv(k, hq // hkv)
    v = _expand_kv(v, hq // hkv)
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = _apply_softcap(s, softcap)
    mask = tm.dense_mask(sq, k.shape[2], causal=causal, window=window,
                         q_offset=q_offset)[None, None]
    if kv_len is not None:
        kvm = jnp.arange(k.shape[2])[None, None, None, :] < \
            jnp.asarray(kv_len).reshape(b, 1, 1, 1)
        mask = mask & kvm
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "q_offset",
                     "block_kv"))
def flash_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    q_offset: int = 0,
                    kv_len: Optional[jax.Array] = None,
                    block_kv: int = 512) -> jax.Array:
    """Chunked online-softmax attention (the kernel's algorithm, in jnp).

    Scans over KV chunks of ``block_kv``; maintains running (m, l, acc)
    exactly as the kernel does.  Future-only chunks are excluded from the
    scan range statically (the grid-level part of the paper's block skip).
    """
    out, _ = flash_reference_with_lse(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, kv_len=kv_len, block_kv=block_kv)
    return out


def flash_reference_with_lse(q, k, v, *, causal=True, window=None,
                             softcap=None, scale=None, q_offset=0,
                             kv_len=None, block_kv=512):
    """Like flash_reference but also returns logsumexp (for CP merging).

    ``q_offset`` may be a static int (enables the static grid-level skip)
    or a traced scalar / (B,) int32 array of per-sequence offsets (chunked
    paged prefill: one trace serves every chunk position).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32)

    static_offset = isinstance(q_offset, int)
    block_kv = min(block_kv, skv)
    n_chunks = (skv + block_kv - 1) // block_kv
    # Static grid-level skip: with causal masking, chunks entirely in the
    # future of the last query row never contribute.  Only possible when
    # the offset is known at trace time; dynamic offsets fall back to
    # scanning every chunk (masking keeps them correct).
    if causal and static_offset:
        last_q = q_offset + sq - 1
        n_chunks = min(n_chunks, last_q // block_kv + 1)
    pad = n_chunks * block_kv - min(skv, n_chunks * block_kv)
    usable = n_chunks * block_kv
    kc = k[:, :, :usable]
    vc = v[:, :, :usable]
    if pad or usable > skv:
        pad_n = usable - skv
        if pad_n > 0:
            kc = jnp.pad(k, ((0, 0), (0, 0), (0, pad_n), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, 0), (0, pad_n), (0, 0)))
    # (n_chunks, B, Hkv, block_kv, D)
    kc = kc.reshape(b, hkv, n_chunks, block_kv, d).transpose(2, 0, 1, 3, 4)
    vc = vc.reshape(b, hkv, n_chunks, block_kv, d).transpose(2, 0, 1, 3, 4)

    # (B, Sq) global query positions; scalar offsets broadcast over batch
    q_pos = (jnp.asarray(q_offset, jnp.int32).reshape(-1, 1)
             + jnp.arange(sq, dtype=jnp.int32))
    effective_kv = jnp.minimum(
        jnp.asarray(kv_len if kv_len is not None else skv), skv)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        j, k_j, v_j = inp
        k_j = _expand_kv(k_j, n_rep).astype(jnp.float32)
        v_j = _expand_kv(v_j, n_rep).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, k_j) * scale
        s = _apply_softcap(s, softcap)
        kv_pos = j * block_kv + jnp.arange(block_kv)
        mask = jnp.ones((q_pos.shape[0], sq, block_kv), jnp.bool_)
        if causal:
            mask = mask & (q_pos[:, :, None] >= kv_pos[None, None, :])
        if window is not None:
            mask = mask & (q_pos[:, :, None] - kv_pos[None, None, :]
                           < window)
        maskb = mask[:, None] & \
            (kv_pos[None, None, None, :] <
             jnp.asarray(effective_kv).reshape(-1, 1, 1, 1))
        s = jnp.where(maskb, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, v_j)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc))
    l_safe = jnp.where(l == 0, 1.0, l)
    out = (acc / l_safe[..., None]).astype(q.dtype)
    lse = m + jnp.log(l_safe)
    return out, lse


def decode_reference(q, k_cache, v_cache, kv_len, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-token decode attention oracle.

    q: (B, Hq, 1, D); caches: (B, Hkv, S, D); kv_len: (B,) current lengths
    (the new token's position is kv_len - 1).
    """
    b = q.shape[0]
    q_off = 0  # positions handled through kv_len masking below
    s = k_cache.shape[2]
    hq, hkv = q.shape[1], k_cache.shape[1]
    k = _expand_kv(k_cache, hq // hkv).astype(jnp.float32)
    v = _expand_kv(v_cache, hq // hkv).astype(jnp.float32)
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k) * scale
    logits = _apply_softcap(logits, softcap)
    pos = jnp.arange(s)[None, None, None, :]
    lens = jnp.asarray(kv_len).reshape(b, 1, 1, 1)
    mask = pos < lens
    if window is not None:
        mask = mask & (pos >= lens - window)
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v).astype(q.dtype)
