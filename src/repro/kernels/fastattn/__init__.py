from repro.kernels.fastattn.ops import fastattn  # noqa: F401
