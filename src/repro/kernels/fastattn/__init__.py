from repro.kernels.fastattn.ops import (fastattn,  # noqa: F401
                                        fastattn_paged_prefill)
