"""Jit'd public wrapper for the FastAttention kernel.

``fastattn`` dispatches between the Pallas TPU kernel, interpret mode
(CPU validation), and the pure-jnp flash reference, and attaches a
recompute-based backward (custom_vjp) so the op is usable in training.
``fastattn_paged_prefill`` is the inference-only chunked-prefill variant
that reads K/V straight from the paged pools through the page table.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fastattn import kernel as _kernel
from repro.kernels.fastattn import ref as _ref


@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10, 11, 12))
def fastattn(q, k, v,
             causal: bool = True,
             window: Optional[int] = None,
             softcap: Optional[float] = None,
             scale: Optional[float] = None,
             q_offset: int = 0,
             block_q: int = 256,
             block_kv1: int = 1024,
             block_kv2: int = 256,
             impl: str = "pallas",
             kv_valid: Optional[int] = None):
    """FastAttention: (B, Hq, Sq, D) x (B, Hkv, Skv, D) -> (B, Hq, Sq, D).

    impl: 'pallas' (TPU), 'interpret' (Pallas on CPU for validation), or
    'reference' (pure jnp; used for CPU dry-runs / as backward).
    ``kv_valid`` (static) masks K/V rows past that length -- the tail of a
    gathered paged view whose last page is only partially filled.
    """
    if impl in ("pallas", "interpret"):
        return _kernel.fastattn_fwd(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset, kv_valid=kv_valid,
            block_q=block_q, block_kv1=block_kv1, block_kv2=block_kv2,
            interpret=(impl == "interpret"))
    return _ref.flash_reference(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, kv_len=kv_valid, block_kv=block_kv1)


def _fwd(q, k, v, causal, window, softcap, scale, q_offset,
         block_q, block_kv1, block_kv2, impl, kv_valid):
    out = fastattn(q, k, v, causal, window, softcap, scale, q_offset,
                   block_q, block_kv1, block_kv2, impl, kv_valid)
    return out, (q, k, v)


def _bwd(causal, window, softcap, scale, q_offset,
         block_q, block_kv1, block_kv2, impl, kv_valid, res, g):
    # Recompute-based backward through the flash reference (same numerics,
    # linear memory).  On TPU the fwd ran the Pallas kernel; the bwd is a
    # standard-XLA chunked recompute -- documented in DESIGN.md §7.
    q, k, v = res

    def f(q, k, v):
        return _ref.flash_reference(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset, kv_len=kv_valid,
            block_kv=block_kv1)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


fastattn.defvjp(_fwd, _bwd)


def fastattn_paged_prefill(q, k_pages, v_pages, page_table, pos_start,
                           kv_len, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None,
                           block_q: int = 256,
                           interpret: bool = False):
    """Chunked-prefill attention against the paged KV pools (no vjp --
    serving only).  q: (B, Hq, Sq, D); pages (Hkv, P, page_size, D);
    page_table (B, n_kv) int32; pos_start/kv_len (B,) int32 runtime
    offsets (scalar-prefetched: one trace per chunk *shape*, not per
    chunk position)."""
    return _kernel.paged_prefill_fwd(
        q, k_pages, v_pages, page_table, pos_start, kv_len,
        window=window, softcap=softcap, scale=scale, block_q=block_q,
        interpret=interpret)
