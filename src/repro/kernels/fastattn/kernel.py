"""FastAttention forward kernel: two-level tiling on TPU (paper §4.1).

Level 1 (paper: GM -> L1, large blocks, double buffered):
    the Pallas grid streams K/V *macro-blocks* of ``block_kv1`` rows from
    HBM into VMEM; Pallas' software pipeline double-buffers these DMAs so
    transfer of macro-block n+1 overlaps compute on n.  Large level-1
    blocks amortize DMA setup and cut the number of grid synchronizations
    -- the Ascend Cube<->Vector sync the paper eliminates.

Level 2 (paper: L1 -> L0, small blocks, Cube/Vector pipelining):
    inside the kernel a ``fori_loop`` walks ``block_kv1 // block_kv2``
    MXU-aligned *sub-tiles*.  Per sub-tile the MXU computes Q @ K_sub^T
    while the VPU applies softcap/mask/online-softmax -- back-to-back ops
    the Mosaic compiler pipelines across sub-tiles (the Cube/Vector overlap
    of Figure 2).

Tiling-mask (paper §4.1, T2): a single (2M)x(2M) lower-triangular M-mask in
VMEM generates every B-mask by shifted ``dynamic_slice``; sub-tiles are
classified SKIP / PARTIAL / FULL.  SKIP blocks are pruned both at the grid
level (the KV index map clamps to the last valid macro-block, so pruned
blocks are neither fetched nor computed) and at sub-tile level (pl.when).
FULL blocks skip the mask add entirely (the Vector-unit saving).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core import tiling_mask as tm

NEG_INF = -1e30
LANES = 128


def _kernel(q_ref, k_ref, v_ref, mmask_ref, o_ref,
            acc_ref, m_ref, l_ref, *,
            causal: bool, window: Optional[int], softcap: Optional[float],
            scale: float, q_offset: int, kv_valid: int,
            block_q: int, block_kv1: int, block_kv2: int,
            n_kv1: int, mm: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    q_start = q_offset + qi * block_q          # global position of q row 0
    q_end = q_start + block_q - 1

    # ---- level-1 block validity (grid-level skip) -------------------------
    last_valid = n_kv1 - 1
    if causal:
        last_valid = jnp.minimum(last_valid, q_end // block_kv1)
    last_valid = jnp.minimum(last_valid, (kv_valid - 1) // block_kv1)
    first_valid = 0
    if window is not None:
        first_valid = jnp.maximum(
            0, (q_start - window + 1) // block_kv1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when((ki >= first_valid) & (ki <= last_valid))
    def _compute():
        q = q_ref[0, 0]                        # (block_q, d)
        n_sub = block_kv1 // block_kv2

        def sub_tile(j, _):
            kv_start = ki * block_kv1 + j * block_kv2
            kv_end = kv_start + block_kv2 - 1
            delta = q_start - kv_start

            # ---- sub-tile classification (T2) --------------------------
            skip = jnp.bool_(False)
            full = jnp.bool_(True)
            if causal:
                skip = skip | (delta <= -block_q)
                full = full & (delta >= block_kv2 - 1)
            if window is not None:
                skip = skip | (kv_end <= q_start - window)
                full = full & (kv_start >= q_end - window + 1)
            pad_tail = kv_valid % block_kv2 != 0 or True
            skip = skip | (kv_start >= kv_valid)
            full = full & (kv_end < kv_valid)

            @pl.when(~skip)
            def _do():
                k_sub = k_ref[0, 0, pl.ds(j * block_kv2, block_kv2), :]
                # MXU: scores in f32
                s = jax.lax.dot_general(
                    q, k_sub, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32) * scale
                if softcap is not None:
                    s = softcap * jnp.tanh(s / softcap)

                def _masked(s):
                    # B-mask = shifted slice(s) of the M-mask (VPU work,
                    # only on PARTIAL sub-tiles).
                    bm = tm.slice_bmask(mmask_ref[...], delta,
                                        block_q, block_kv2)
                    if window is not None:
                        low = tm.slice_bmask(mmask_ref[...], delta - window,
                                             block_q, block_kv2)
                        bm = bm * (1 - low)
                    # KV-padding rows: single-row slice broadcast
                    # B[r,c] = (kv_valid - kv_start - 1 >= c).
                    prow = tm.slice_bmask(
                        mmask_ref[...],
                        jnp.clip(kv_valid - kv_start - 1, -mm, mm),
                        1, block_kv2)
                    bm = bm * prow
                    return jnp.where(bm != 0, s, NEG_INF)

                s = jax.lax.cond(full, lambda s: s, _masked, s)

                # ---- online softmax update (VPU) ------------------------
                m_prev = m_ref[...]                       # (block_q, LANES)
                m_cur = jnp.max(s, axis=1, keepdims=True)  # (block_q, 1)
                m_cur = jnp.broadcast_to(m_cur, m_prev.shape)
                m_new = jnp.maximum(m_prev, m_cur)
                alpha = jnp.exp(m_prev - m_new)            # (block_q, LANES)
                p = jnp.exp(s - m_new[:, :1])
                l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
                    jnp.sum(p, axis=1, keepdims=True), m_prev.shape)
                pv = jax.lax.dot_general(
                    p.astype(v_ref.dtype),
                    v_ref[0, 0, pl.ds(j * block_kv2, block_kv2), :],
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                acc_ref[...] = acc_ref[...] * alpha[:, :1] + pv
                m_ref[...] = m_new

            return 0

        jax.lax.fori_loop(0, n_sub, sub_tile, 0, unroll=True)

    @pl.when(ki == n_kv1 - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "q_offset",
                     "kv_valid", "block_q", "block_kv1", "block_kv2",
                     "interpret"))
def fastattn_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool = True,
                 window: Optional[int] = None,
                 softcap: Optional[float] = None,
                 scale: Optional[float] = None,
                 q_offset: int = 0,
                 kv_valid: Optional[int] = None,
                 block_q: int = 256,
                 block_kv1: int = 1024,
                 block_kv2: int = 256,
                 interpret: bool = False) -> jax.Array:
    """Two-level-tiled FlashAttention2 forward on TPU.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D), Hq % Hkv == 0.
    Sequence lengths need not be multiples of the block sizes (padded
    internally; padding masked through the M-mask row trick).
    ``kv_valid`` marks only the first rows of K/V as real (a gathered
    paged view whose last page is partially filled); the tail is masked
    exactly like internal padding.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    n_rep = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kv_valid = skv if kv_valid is None else min(kv_valid, skv)

    block_q = min(block_q, max(sq, 8))
    block_kv2 = min(block_kv2, block_kv1)
    # pad sequences to block multiples
    sq_p = (sq + block_q - 1) // block_q * block_q
    skv_p = (skv + block_kv1 - 1) // block_kv1 * block_kv1
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))

    n_q = sq_p // block_q
    n_kv1 = skv_p // block_kv1
    mm = max(block_q, block_kv2)
    mmask = tm.make_m_mask(mm, jnp.int8)

    grid = (b, hq, n_q, n_kv1)

    def q_map(bi, hi, qi, ki):
        return (bi, hi, qi, 0)

    def kv_map(bi, hi, qi, ki):
        # Grid-level skip: clamp pruned blocks onto the nearest valid one so
        # the pipeline does not re-DMA them (consecutive identical indices
        # reuse the resident VMEM buffer).
        last = jnp.minimum(n_kv1 - 1, (kv_valid - 1) // block_kv1)
        if causal:
            q_end = q_offset + (qi + 1) * block_q - 1
            last = jnp.minimum(last, q_end // block_kv1)
        ki = jnp.minimum(ki, last)
        if window is not None:
            first = jnp.maximum(
                0, (q_offset + qi * block_q - window + 1) // block_kv1)
            ki = jnp.maximum(ki, first)
        return (bi, hi // n_rep, ki, 0)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, kv_valid=kv_valid, block_q=block_q,
        block_kv1=block_kv1, block_kv2=block_kv2, n_kv1=n_kv1, mm=mm)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), q_map),
            pl.BlockSpec((1, 1, block_kv1, d), kv_map),
            pl.BlockSpec((1, 1, block_kv1, d), kv_map),
            pl.BlockSpec((2 * mm, 2 * mm), lambda *_: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),      # acc
            pltpu.VMEM((block_q, LANES), jnp.float32),  # running max
            pltpu.VMEM((block_q, LANES), jnp.float32),  # running sum
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, mmask)
    return out[:, :, :sq, :]


# ---------------------------------------------------------------------------
# Paged chunked prefill: a block of prompt tokens against the KV page pools
# ---------------------------------------------------------------------------

def _paged_prefill_kernel(pt_ref, start_ref, len_ref, q_ref, k_ref, v_ref,
                          o_ref, acc_ref, m_ref, l_ref, *,
                          window: Optional[int], softcap: Optional[float],
                          scale: float, block_q: int, page_size: int,
                          n_kv: int):
    bi = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    q_start = start_ref[bi] + qi * block_q     # global position of q row 0
    kv_len = len_ref[bi]

    # ---- level-1 page validity (grid-level skip, dynamic offsets) ---------
    last_valid = jnp.minimum((q_start + block_q - 1) // page_size,
                             jnp.maximum(kv_len - 1, 0) // page_size)
    first_valid = 0
    if window is not None:
        first_valid = jnp.maximum(0, (q_start - window + 1) // page_size)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when((ki >= first_valid) & (ki <= last_valid))
    def _compute():
        q = q_ref[0, 0]                        # (block_q, d)
        k = k_ref[0, 0]                        # (page_size, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        # Chunk offsets are per-sequence runtime values, so the mask is
        # arithmetic (iota) rather than the static M-mask slice trick.
        rows = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, page_size), 0)
        cols = ki * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, page_size), 1)
        mask = (rows >= cols) & (cols < kv_len)
        if window is not None:
            mask = mask & (rows - cols < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                        # (block_q, LANES)
        m_cur = jnp.broadcast_to(jnp.max(s, axis=1, keepdims=True),
                                 m_prev.shape)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1])
        l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
            jnp.sum(p, axis=1, keepdims=True), m_prev.shape)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0],
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + pv
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "scale", "block_q", "interpret"))
def paged_prefill_fwd(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      page_table: jax.Array, pos_start: jax.Array,
                      kv_len: jax.Array, *,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      scale: Optional[float] = None,
                      block_q: int = 256,
                      interpret: bool = False) -> jax.Array:
    """Causal prefill of one prompt chunk against the paged KV pools.

    q: (B, Hq, Sq, D) -- the chunk's queries, already RoPE'd at their
    global positions; pages: (Hkv, P, page_size, D) global pools (the
    chunk's K/V rows must already be scattered in); page_table: (B, n_kv)
    int32; pos_start: (B,) int32 global position of the chunk's first
    token; kv_len: (B,) int32 valid KV length (= pos_start + valid chunk
    tokens).  All offsets are runtime values fed through scalar prefetch,
    so one trace serves every chunk of every prompt: the KV BlockSpec
    index map resolves logical block ki -> page_table[b, ki] and clamps
    to the causally-valid page range of the chunk (the grid-level
    tiling-mask skip of the dense kernel, with dynamic bounds).
    Returns (B, Hq, Sq, D).
    """
    b, hq, sq, d = q.shape
    hkv, _, page_size, _ = k_pages.shape
    n_kv = page_table.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    n_rep = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    block_q = min(block_q, max(sq, 8))
    sq_p = (sq + block_q - 1) // block_q * block_q
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    n_q = sq_p // block_q

    def q_map(bi, hi, qi, ki, pt_ref, start_ref, len_ref):
        return (bi, hi, qi, 0)

    def kv_map(bi, hi, qi, ki, pt_ref, start_ref, len_ref):
        # clamp pruned logical blocks onto the nearest valid one so the
        # pipeline re-uses the resident page instead of DMAing a new one
        q_end = start_ref[bi] + (qi + 1) * block_q - 1
        last = jnp.minimum(q_end // page_size,
                           jnp.maximum(len_ref[bi] - 1, 0) // page_size)
        kj = jnp.minimum(ki, last)
        if window is not None:
            first = jnp.maximum(
                0, (start_ref[bi] + qi * block_q - window + 1) // page_size)
            kj = jnp.maximum(kj, first)
        # fully-padded q blocks of the last chunk can push `first` (and
        # thus kj) past the table width -- clamp so the scalar-prefetch
        # read stays in bounds (their rows are masked in the kernel)
        return (hi // n_rep, pt_ref[bi, jnp.minimum(kj, n_kv - 1)], 0, 0)

    kernel = functools.partial(
        _paged_prefill_kernel, window=window, softcap=softcap, scale=scale,
        block_q=block_q, page_size=page_size, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(b, hq, n_q, n_kv),
            in_specs=[
                pl.BlockSpec((1, 1, block_q, d), q_map),
                pl.BlockSpec((1, 1, page_size, d), kv_map),
                pl.BlockSpec((1, 1, page_size, d), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, block_q, d), q_map),
            scratch_shapes=[
                pltpu.VMEM((block_q, d), jnp.float32),      # acc
                pltpu.VMEM((block_q, LANES), jnp.float32),  # running max
                pltpu.VMEM((block_q, LANES), jnp.float32),  # running sum
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos_start.astype(jnp.int32),
      kv_len.astype(jnp.int32), q, k_pages, v_pages)
    return out[:, :, :sq, :]
