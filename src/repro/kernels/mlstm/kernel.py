"""Chunkwise mLSTM Pallas kernel (beyond-paper; powers the xLSTM arch).

Grid (B, H, n_chunks), sequential over chunks; the (C, n, m) recurrent
state lives in VMEM/SMEM scratch and carries across grid steps.  Per chunk
the kernel computes the intra-chunk quadratic term on the MXU and folds in
the inter-chunk state, exactly mirroring ``ref.mlstm_chunkwise``.

TPU-specific trick: 1-D gate vectors are kept as (1, L) rows; column
versions are produced by an identity matmul (vector transpose on the MXU)
because Mosaic has no cheap (1, L) -> (L, 1) relayout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, ig_ref, fg_ref,
            h_ref, cfin_ref, nfin_ref, mfin_ref,
            c_ref, n_ref, m_ref, *,
            chunk: int, n_chunks: int, dk: int, dv: int, seq_len: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[0, 0] = NEG_INF

    q = q_ref[0, 0].astype(jnp.float32) * dk ** -0.5       # (L, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    ig = ig_ref[0, 0].astype(jnp.float32)                  # (1, L)
    lf = -jax.nn.softplus(-fg_ref[0, 0].astype(jnp.float32))

    # mask gate positions past the true sequence end
    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
    ig = jnp.where(pos < seq_len, ig, NEG_INF)
    lf = jnp.where(pos < seq_len, lf, 0.0)

    ident = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) ==
             jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
             ).astype(jnp.float32)
    upper_incl = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) <=
                  jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
                  ).astype(jnp.float32)
    tril = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >=
            jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))

    def row2col(x):                                        # (1,L) -> (L,1)
        return jax.lax.dot_general(ident, x, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    bsum = jax.lax.dot_general(lf, upper_incl, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (1,L)
    bsum_col = row2col(bsum)                               # (L,1)
    btot = bsum_col[chunk - 1, 0]
    m_prev = m_ref[0, 0]
    n_prev = n_ref[0:1, :]                                 # (1, dk)
    C_prev = c_ref[...]                                    # (dk, dv)

    # ---- intra-chunk decay matrix ------------------------------------
    D = bsum_col - bsum + ig                               # (L, L)
    D = jnp.where(tril, D, NEG_INF)
    m_intra = jnp.max(D, axis=1, keepdims=True)            # (L,1)
    m_inter = m_prev + bsum_col
    m_row = jnp.maximum(m_intra, m_inter)
    w = jnp.exp(D - m_row)
    w = jnp.where(tril, w, 0.0)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * w
    num = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    nrow = jax.lax.dot_general(w, k, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    wi = jnp.exp(m_inter - m_row)                          # (L,1)
    num = num + wi * jax.lax.dot_general(
        q, C_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    nrow = nrow + wi * n_prev
    qn = jnp.sum(q * nrow, axis=1, keepdims=True)          # (L,1)
    den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_row))
    h_ref[0, 0] = (num / den).astype(h_ref.dtype)

    # ---- state update --------------------------------------------------
    m_new = jnp.maximum(m_prev + btot, jnp.max(btot - bsum + ig))
    wC = jnp.exp(m_prev + btot - m_new)
    wk = jnp.exp(btot - bsum + ig - m_new)                 # (1,L)
    kw = k * row2col(wk)                                   # (L, dk)
    c_ref[...] = wC * C_prev + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_new = wC * n_prev + jax.lax.dot_general(
        wk, k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (1, dk)
    n_ref[...] = jnp.broadcast_to(n_new, n_ref.shape)
    m_ref[0, 0] = m_new

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        cfin_ref[0, 0] = c_ref[...]
        nfin_ref[0, 0] = n_ref[...]
        mfin_ref[0, 0] = jnp.full_like(mfin_ref[0, 0], m_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunkwise_fwd(q, k, v, i_gate, f_gate, *, chunk: int = 128,
                        interpret: bool = False):
    """q,k: (B,H,S,dk); v: (B,H,S,dv); gates: (B,H,S).

    Returns (h (B,H,S,dv), (C (B,H,dk,dv), n (B,H,dk), m (B,H))).
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, max(s, 8))
    pad = (-s) % chunk
    if pad:
        padw = ((0, 0), (0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        i_gate = jnp.pad(i_gate, ((0, 0), (0, 0), (0, pad)))
        f_gate = jnp.pad(f_gate, ((0, 0), (0, 0), (0, pad)))
    sp = s + pad
    nc = sp // chunk
    igc = i_gate.reshape(b, h, nc, chunk)
    fgc = f_gate.reshape(b, h, nc, chunk)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nc, dk=dk,
                               dv=dv, seq_len=s)
    out_shapes = (
        jax.ShapeDtypeStruct((b, h, sp, dv), q.dtype),
        jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
        jax.ShapeDtypeStruct((b, h, 8, dk), jnp.float32),
        jax.ShapeDtypeStruct((b, h, 8, 128), jnp.float32),
    )
    hs, cfin, nfin, mfin = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dk), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, dv), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, chunk, dv), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, 8, dk), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, 8, 128), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ),
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((8, dk), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, igc, fgc)
    state = (cfin, nfin[:, :, 0], mfin[:, :, 0, 0])
    return hs[:, :, :s], state
