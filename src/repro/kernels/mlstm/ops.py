"""Jit'd wrapper for the chunkwise mLSTM kernel with recompute backward."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mlstm import kernel as _kernel
from repro.kernels.mlstm import ref as _ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def mlstm_chunkwise(q, k, v, i_gate, f_gate, chunk: int = 128,
                    impl: str = "reference"):
    """Chunkwise mLSTM: returns h (B,H,S,dv) only (state-less API).

    impl: 'pallas' | 'interpret' | 'reference'.
    """
    if impl in ("pallas", "interpret"):
        h, _ = _kernel.mlstm_chunkwise_fwd(
            q, k, v, i_gate, f_gate, chunk=chunk,
            interpret=(impl == "interpret"))
        return h
    return _ref.mlstm_chunkwise(q, k, v, i_gate, f_gate, chunk=chunk)


def _fwd(q, k, v, i_gate, f_gate, chunk, impl):
    return mlstm_chunkwise(q, k, v, i_gate, f_gate, chunk, impl), \
        (q, k, v, i_gate, f_gate)


def _bwd(chunk, impl, res, g):
    q, k, v, i_gate, f_gate = res

    def f(q, k, v, ig, fg):
        return _ref.mlstm_chunkwise(q, k, v, ig, fg, chunk=chunk)

    _, vjp = jax.vjp(f, q, k, v, i_gate, f_gate)
    return vjp(g)


mlstm_chunkwise.defvjp(_fwd, _bwd)
