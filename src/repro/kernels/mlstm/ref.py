"""Pure-jnp oracles for the mLSTM cell (xLSTM, arXiv:2405.04517).

Stabilized matrix-LSTM:
    logf_t = logsigmoid(ftilde_t)
    m_t    = max(logf_t + m_{t-1}, itilde_t)
    f'_t   = exp(logf_t + m_{t-1} - m_t);   i'_t = exp(itilde_t - m_t)
    C_t    = f'_t C_{t-1} + i'_t k_t v_t^T          (d_k x d_v)
    n_t    = f'_t n_{t-1} + i'_t k_t
    h_t    = (q_t C_t) / max(|q_t . n_t|, exp(-m_t))     q scaled d_k^-1/2

Three equivalent forms: ``mlstm_recurrent`` (scan; decode path),
``mlstm_parallel`` (quadratic masked; short-seq oracle) and
``mlstm_chunkwise`` (linear in S; the kernel's algorithm).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _logsigmoid(x):
    return -jax.nn.softplus(-x)


def mlstm_recurrent(q, k, v, i_gate, f_gate, initial_state=None):
    """Sequential oracle.

    q,k: (B, H, S, dk); v: (B, H, S, dv); gates: (B, H, S).
    Returns (h, state): h (B, H, S, dv);
    state = (C (B,H,dk,dv), n (B,H,dk), m (B,H)).
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    q = q.astype(jnp.float32) * dk ** -0.5
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    logf = _logsigmoid(f_gate.astype(jnp.float32))
    i_gate = i_gate.astype(jnp.float32)

    if initial_state is None:
        C0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
        m0 = jnp.full((b, h), NEG_INF, jnp.float32)
    else:
        C0, n0, m0 = initial_state

    def step(carry, x):
        C, n, m = carry
        qt, kt, vt, it, lft = x
        m_new = jnp.maximum(lft + m, it)
        fp = jnp.exp(lft + m - m_new)[..., None, None]
        ip = jnp.exp(it - m_new)[..., None, None]
        C = fp * C + ip * (kt[..., :, None] * vt[..., None, :])
        n = fp[..., 0] * n + ip[..., 0] * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n)),
                          jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    xs = (q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), i_gate.transpose(2, 0, 1),
          logf.transpose(2, 0, 1))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 2, 0, 3), (C, n, m)


def mlstm_parallel(q, k, v, i_gate, f_gate):
    """Quadratic masked oracle (no chunking)."""
    b, h, s, dk = q.shape
    q = q.astype(jnp.float32) * dk ** -0.5
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    logf = _logsigmoid(f_gate.astype(jnp.float32))
    i_gate = i_gate.astype(jnp.float32)
    bsum = jnp.cumsum(logf, axis=-1)                       # (B,H,S)
    # D[i,j] = b_i - b_j + itilde_j  for j <= i
    D = bsum[..., :, None] - bsum[..., None, :] + i_gate[..., None, :]
    mask = jnp.tril(jnp.ones((s, s), bool))
    D = jnp.where(mask, D, NEG_INF)
    m = jnp.max(D, axis=-1)                                # (B,H,S)
    w = jnp.exp(D - m[..., None])
    scores = jnp.einsum("bhid,bhjd->bhij", q, k) * w
    num = jnp.einsum("bhij,bhjv->bhiv", scores, v)
    nvec = jnp.einsum("bhij,bhjd->bhid", w, k)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhid,bhid->bhi", q, nvec)),
                      jnp.exp(-m))
    return num / den[..., None]


def mlstm_chunkwise(q, k, v, i_gate, f_gate, *, chunk: int = 128,
                    initial_state=None, return_state: bool = False):
    """Chunk-parallel form: intra-chunk quadratic + inter-chunk recurrence.

    This is the exact algorithm the Pallas kernel implements.
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0),) * 2 + ((0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0),) * 2 + ((0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0),) * 2 + ((0, pad), (0, 0)))
        i_gate = jnp.pad(i_gate, ((0, 0),) * 2 + ((0, pad),),
                         constant_values=NEG_INF)
        f_gate = jnp.pad(f_gate, ((0, 0),) * 2 + ((0, pad),),
                         constant_values=30.0)   # logf ~ 0 for padding
    sp = s + pad
    n_chunks = sp // chunk

    qf = q.astype(jnp.float32) * dk ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logf = _logsigmoid(f_gate.astype(jnp.float32))
    ig = i_gate.astype(jnp.float32)

    def to_chunks(x):
        return x.reshape(b, h, n_chunks, chunk, *x.shape[4:]) \
            if x.ndim == 5 else x.reshape(b, h, n_chunks, chunk)

    qc = qf.reshape(b, h, n_chunks, chunk, dk).transpose(2, 0, 1, 3, 4)
    kc = kf.reshape(b, h, n_chunks, chunk, dk).transpose(2, 0, 1, 3, 4)
    vc = vf.reshape(b, h, n_chunks, chunk, dv).transpose(2, 0, 1, 3, 4)
    igc = ig.reshape(b, h, n_chunks, chunk).transpose(2, 0, 1, 3)
    lfc = logf.reshape(b, h, n_chunks, chunk).transpose(2, 0, 1, 3)

    if initial_state is None:
        C0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
        m0 = jnp.full((b, h), NEG_INF, jnp.float32)
    else:
        C0, n0, m0 = initial_state

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, xs):
        C, n, m = xs_step(carry, xs)
        return C, n, m

    def xs_step(carry, xs):
        C, n, m = carry
        qi, ki, vi, ii, lf = xs
        bsum = jnp.cumsum(lf, axis=-1)                     # (B,H,L)
        btot = bsum[..., -1]                               # (B,H)
        # ---- per-row stabilizer -------------------------------------
        Dt = bsum[..., :, None] - bsum[..., None, :] + ii[..., None, :]
        Dt = jnp.where(tri, Dt, NEG_INF)
        m_intra = jnp.max(Dt, axis=-1)                     # (B,H,L)
        m_inter = m[..., None] + bsum                      # (B,H,L)
        m_row = jnp.maximum(m_intra, m_inter)
        # ---- intra-chunk ---------------------------------------------
        w = jnp.exp(Dt - m_row[..., None])
        scores = jnp.einsum("bhid,bhjd->bhij", qi, ki) * w
        num = jnp.einsum("bhij,bhjv->bhiv", scores, vi)
        nrow = jnp.einsum("bhij,bhjd->bhid", w, ki)
        # ---- inter-chunk (state) -------------------------------------
        wi = jnp.exp(m_inter - m_row)                      # (B,H,L)
        num = num + wi[..., None] * jnp.einsum("bhid,bhdv->bhiv", qi, C)
        nrow = nrow + wi[..., None] * n[..., None, :]
        den = jnp.maximum(jnp.abs(jnp.einsum("bhid,bhid->bhi", qi, nrow)),
                          jnp.exp(-m_row))
        h_out = num / den[..., None]
        # ---- state update --------------------------------------------
        m_new = jnp.maximum(m + btot,
                            jnp.max(btot[..., None] - bsum + ii, axis=-1))
        wC = jnp.exp(m + btot - m_new)                     # (B,H)
        wk = jnp.exp(btot[..., None] - bsum + ii - m_new[..., None])
        C = wC[..., None, None] * C + jnp.einsum(
            "bhj,bhjd,bhjv->bhdv", wk, ki, vi)
        n = wC[..., None] * n + jnp.einsum("bhj,bhjd->bhd", wk, ki)
        return (C, n, m_new), h_out

    (C, n, m), hs = jax.lax.scan(xs_step, (C0, n0, m0),
                                 (qc, kc, vc, igc, lfc))
    out = hs.transpose(1, 2, 0, 3, 4).reshape(b, h, sp, dv)[:, :, :s]
    if return_state:
        return out, (C, n, m)
    return out
