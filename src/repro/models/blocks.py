"""Per-layer block definitions + caches for every block kind.

A block is (init, logical, apply, init_cache); models/lm.py composes
segments of homogeneous blocks with lax.scan.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import attention as attn_mod
from repro.layers import mlp as mlp_mod
from repro.layers import moe as moe_mod
from repro.layers import ssm as ssm_mod
from repro.layers.norms import apply_norm, init_norm, norm_logical
from repro.sharding.rules import constrain


def _window(cfg: ModelConfig, kind: str) -> Optional[int]:
    return cfg.window_size if kind.endswith("local") else None


# ---------------------------------------------------------------------------
# init / logical
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str, dtype):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: dict = {"ln1": init_norm(d, cfg.norm_type, dtype)}
    if kind in ("attn", "attn_local", "moe", "hymba", "hymba_local"):
        p["attn"] = attn_mod.init_attention(ks[0], cfg, dtype)
        p["ln2"] = init_norm(d, cfg.norm_type, dtype)
        if kind == "moe":
            p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        elif cfg.d_ff:
            p["mlp"] = mlp_mod.init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_type,
                                        dtype)
        if cfg.post_norm:
            p["ln1_post"] = init_norm(d, cfg.norm_type, dtype)
            p["ln2_post"] = init_norm(d, cfg.norm_type, dtype)
    if kind in ("hymba", "hymba_local"):
        p["mamba"] = ssm_mod.init_mamba(ks[2], cfg, dtype)
        p["norm_attn"] = init_norm(d, cfg.norm_type, dtype)
        p["norm_mamba"] = init_norm(d, cfg.norm_type, dtype)
    if kind == "mlstm":
        p["cell"] = ssm_mod.init_mlstm(ks[3], cfg, dtype)
    if kind == "slstm":
        p["cell"] = ssm_mod.init_slstm(ks[4], cfg, dtype)
    return p


def block_logical(cfg: ModelConfig, kind: str):
    d = cfg.d_model
    p: dict = {"ln1": norm_logical(d, cfg.norm_type)}
    if kind in ("attn", "attn_local", "moe", "hymba", "hymba_local"):
        p["attn"] = attn_mod.attention_logical(cfg)
        p["ln2"] = norm_logical(d, cfg.norm_type)
        if kind == "moe":
            p["moe"] = moe_mod.moe_logical(cfg)
        elif cfg.d_ff:
            p["mlp"] = mlp_mod.mlp_logical(d, cfg.d_ff, cfg.mlp_type)
        if cfg.post_norm:
            p["ln1_post"] = norm_logical(d, cfg.norm_type)
            p["ln2_post"] = norm_logical(d, cfg.norm_type)
    if kind in ("hymba", "hymba_local"):
        p["mamba"] = ssm_mod.mamba_logical(cfg)
        p["norm_attn"] = norm_logical(d, cfg.norm_type)
        p["norm_mamba"] = norm_logical(d, cfg.norm_type)
    if kind == "mlstm":
        p["cell"] = ssm_mod.mlstm_logical(cfg)
    if kind == "slstm":
        p["cell"] = ssm_mod.slstm_logical(cfg)
    return p


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                     dtype):
    if kind in ("attn", "attn_local", "moe"):
        return attn_mod.init_kv_cache(cfg, batch, max_seq, dtype)
    if kind in ("hymba", "hymba_local"):
        di, n = cfg.q_dim, cfg.ssm_state_size
        return {
            "kv": attn_mod.init_kv_cache(cfg, batch, max_seq, dtype),
            "mamba": ssm_mod.MambaState(
                h=jnp.zeros((batch, di, n), jnp.float32),
                conv=jnp.zeros((batch, cfg.conv_kernel - 1, di), dtype)),
        }
    if kind == "mlstm":
        di = int(cfg.d_model * cfg.mlstm_proj_factor)
        nh, hd = cfg.num_heads, int(cfg.d_model * cfg.mlstm_proj_factor
                                    ) // cfg.num_heads
        return ssm_mod.MLSTMState(
            c=jnp.zeros((batch, nh, hd, hd), jnp.float32),
            n=jnp.zeros((batch, nh, hd), jnp.float32),
            m=jnp.full((batch, nh), -1e30, jnp.float32),
            conv=jnp.zeros((0,), dtype))
    if kind == "slstm":
        di = int(cfg.d_model * cfg.mlstm_proj_factor)
        return ssm_mod.SLSTMState(
            c=jnp.zeros((batch, di), jnp.float32),
            n=jnp.zeros((batch, di), jnp.float32),
            h=jnp.zeros((batch, di), jnp.float32),
            m=jnp.full((batch, di), -1e30, jnp.float32))
    raise ValueError(kind)


def init_block_pages(cfg: ModelConfig, kind: str, num_pages: int,
                     page_size: int, dtype):
    """Paged-serving cache for one block: KV page pools for attention
    kinds.  Recurrent kinds (mlstm/slstm/hymba) carry O(1) per-slot state
    -- nothing to page -- and are not yet wired into the paged engine."""
    if kind in ("attn", "attn_local", "moe"):
        return attn_mod.init_kv_pages(cfg, num_pages, page_size, dtype)
    raise NotImplementedError(
        f"paged serving supports attention-cache blocks only, got {kind!r}")


def block_cache_logical(cfg: ModelConfig, kind: str, batch: int,
                        max_seq: int):
    """Logical axes for every cache leaf (mirrors init_block_cache)."""
    if attn_mod.KV_CACHE_LAYOUT == "bhsd":
        kvshape = (batch, cfg.num_kv_heads, max_seq, cfg.head_dim)
        axes = ("batch", "heads", "kv_seq", None)
    else:
        kvshape = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
        axes = ("batch", "kv_seq", "heads", None)
    kv = attn_mod.KVCache(k=(axes, kvshape), v=(axes, kvshape))
    if kind in ("attn", "attn_local", "moe"):
        return kv
    if kind in ("hymba", "hymba_local"):
        di, n = cfg.q_dim, cfg.ssm_state_size
        return {
            "kv": kv,
            "mamba": ssm_mod.MambaState(
                h=(("batch", "channels", None), (batch, di, n)),
                conv=(("batch", None, "channels"),
                      (batch, cfg.conv_kernel - 1, di))),
        }
    if kind == "mlstm":
        di = int(cfg.d_model * cfg.mlstm_proj_factor)
        nh = cfg.num_heads
        hd = di // nh
        return ssm_mod.MLSTMState(
            c=(("batch", None, None, "channels"), (batch, nh, hd, hd)),
            n=(("batch", None, None), (batch, nh, hd)),
            m=(("batch", None), (batch, nh)),
            conv=((None,), (0,)))
    if kind == "slstm":
        di = int(cfg.d_model * cfg.mlstm_proj_factor)
        s2 = (("batch", "channels"), (batch, di))
        return ssm_mod.SLSTMState(c=s2, n=s2, h=s2, m=s2)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _attn_block_tail(params, x, a, cfg: ModelConfig, kind: str):
    """Residual + FFN half of an attention block -- shared by the train,
    dense-decode and paged-decode paths so they cannot diverge."""
    if cfg.post_norm:
        a = apply_norm(params["ln1_post"], a, cfg.norm_type, cfg.norm_eps)
    x = x + a
    h2 = apply_norm(params["ln2"], x, cfg.norm_type, cfg.norm_eps)
    if kind == "moe":
        f = moe_mod.apply_moe(params["moe"], h2, cfg)
    else:
        f = mlp_mod.apply_mlp(params["mlp"], h2, cfg.mlp_type)
    if cfg.post_norm:
        f = apply_norm(params["ln2_post"], f, cfg.norm_type, cfg.norm_eps)
    return x + f


def apply_block(params, x, cfg: ModelConfig, kind: str, *, positions,
                impl: Optional[str] = None):
    d = cfg.d_model
    h = apply_norm(params["ln1"], x, cfg.norm_type, cfg.norm_eps)
    if kind in ("attn", "attn_local", "moe"):
        a = attn_mod.apply_attention(
            params["attn"], h, cfg, positions=positions,
            window=_window(cfg, kind), impl=impl)
        x = _attn_block_tail(params, x, a, cfg, kind)
    elif kind in ("hymba", "hymba_local"):
        a = attn_mod.apply_attention(
            params["attn"], h, cfg, positions=positions,
            window=_window(cfg, kind), impl=impl)
        m = ssm_mod.apply_mamba(params["mamba"], h, cfg)
        fused = 0.5 * (
            apply_norm(params["norm_attn"], a, cfg.norm_type, cfg.norm_eps)
            + apply_norm(params["norm_mamba"], m, cfg.norm_type,
                         cfg.norm_eps))
        x = x + fused
        h2 = apply_norm(params["ln2"], x, cfg.norm_type, cfg.norm_eps)
        x = x + mlp_mod.apply_mlp(params["mlp"], h2, cfg.mlp_type)
    elif kind == "mlstm":
        x = x + ssm_mod.apply_mlstm(params["cell"], h, cfg, impl=impl
                                    if impl in ("pallas", "interpret")
                                    else "reference")
    elif kind == "slstm":
        x = x + ssm_mod.apply_slstm(params["cell"], h, cfg)
    else:
        raise ValueError(kind)
    return constrain(x, "batch", "seq", None)


# ---------------------------------------------------------------------------
# decode (one token, with cache)
# ---------------------------------------------------------------------------

def apply_block_prefill_paged(params, x, cfg: ModelConfig, kind: str,
                              cache, *, page_table, pos_start, n_valid,
                              impl: Optional[str] = None):
    """Chunked paged prefill: one prompt chunk (B, S, D) through the full
    block forward, K/V scattered into the paged pools.  Rows past
    ``n_valid`` are padding (their outputs are garbage, their K/V lands
    in scratch)."""
    if kind not in ("attn", "attn_local", "moe"):
        raise NotImplementedError(
            f"paged serving supports attention-cache blocks only, "
            f"got {kind!r}")
    h = apply_norm(params["ln1"], x, cfg.norm_type, cfg.norm_eps)
    a, cache = attn_mod.apply_attention_prefill_paged(
        params["attn"], h, cfg, cache, page_table=page_table,
        pos_start=pos_start, n_valid=n_valid, window=_window(cfg, kind),
        impl=impl)
    x = _attn_block_tail(params, x, a, cfg, kind)
    return constrain(x, "batch", "seq", None), cache


def apply_block_decode_paged(params, x, cfg: ModelConfig, kind: str,
                             cache, *, page_table, pos,
                             impl: Optional[str] = None):
    """Paged one-token decode: like apply_block_decode but positions are
    per-sequence (B,) and the KV cache is a shared page pool."""
    if kind not in ("attn", "attn_local", "moe"):
        raise NotImplementedError(
            f"paged serving supports attention-cache blocks only, "
            f"got {kind!r}")
    h = apply_norm(params["ln1"], x, cfg.norm_type, cfg.norm_eps)
    a, cache = attn_mod.apply_attention_decode_paged(
        params["attn"], h, cfg, cache, page_table=page_table, pos=pos,
        window=_window(cfg, kind), impl=impl)
    x = _attn_block_tail(params, x, a, cfg, kind)
    return constrain(x, "batch", None, None), cache


def apply_block_decode(params, x, cfg: ModelConfig, kind: str, cache, *,
                       pos, impl: Optional[str] = None):
    h = apply_norm(params["ln1"], x, cfg.norm_type, cfg.norm_eps)
    if kind in ("attn", "attn_local", "moe"):
        a, cache = attn_mod.apply_attention_decode(
            params["attn"], h, cfg, cache, pos=pos,
            window=_window(cfg, kind), impl=impl)
        x = _attn_block_tail(params, x, a, cfg, kind)
    elif kind in ("hymba", "hymba_local"):
        a, kv = attn_mod.apply_attention_decode(
            params["attn"], h, cfg, cache["kv"], pos=pos,
            window=_window(cfg, kind), impl=impl)
        m, mstate = ssm_mod.apply_mamba(params["mamba"], h, cfg,
                                        state=cache["mamba"], decode=True)
        cache = {"kv": kv, "mamba": mstate}
        fused = 0.5 * (
            apply_norm(params["norm_attn"], a, cfg.norm_type, cfg.norm_eps)
            + apply_norm(params["norm_mamba"], m, cfg.norm_type,
                         cfg.norm_eps))
        x = x + fused
        h2 = apply_norm(params["ln2"], x, cfg.norm_type, cfg.norm_eps)
        x = x + mlp_mod.apply_mlp(params["mlp"], h2, cfg.mlp_type)
    elif kind == "mlstm":
        y, cache = ssm_mod.apply_mlstm(params["cell"], h, cfg,
                                       state=cache, decode=True)
        x = x + y
    elif kind == "slstm":
        y, cache = ssm_mod.apply_slstm(params["cell"], h, cfg,
                                       state=cache, decode=True)
        x = x + y
    else:
        raise ValueError(kind)
    return constrain(x, "batch", None, None), cache
