"""Encoder-decoder model (whisper-small backbone).

Encoder: bidirectional attention blocks over stub frame embeddings
(conv frontend replaced by a linear adapter per the assignment).
Decoder: causal self-attention + cross-attention + MLP.  Sinusoidal
positions (whisper's learned decoder table does not scale to the assigned
32K decode shape; documented deviation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig
from repro.layers import attention as attn_mod
from repro.layers import common
from repro.layers import mlp as mlp_mod
from repro.layers.embedding import (embed_tokens, embedding_logical,
                                    init_embedding, lm_logits)
from repro.layers.frontend import (apply_frontend, frontend_logical,
                                   init_frontend)
from repro.layers.norms import apply_norm, init_norm, norm_logical
from repro.sharding.rules import constrain


def sinusoid(positions, d):
    """positions (B,S) -> (B,S,D) sinusoidal embedding."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_block(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_norm(cfg.d_model, cfg.norm_type, dtype),
        "attn": attn_mod.init_attention(ks[0], cfg, dtype),
        "ln2": init_norm(cfg.d_model, cfg.norm_type, dtype),
        "mlp": mlp_mod.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type,
                                dtype),
    }


def _init_dec_block(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg.d_model, cfg.norm_type, dtype),
        "self_attn": attn_mod.init_attention(ks[0], cfg, dtype),
        "ln_x": init_norm(cfg.d_model, cfg.norm_type, dtype),
        "cross_attn": attn_mod.init_attention(ks[1], cfg, dtype),
        "ln2": init_norm(cfg.d_model, cfg.norm_type, dtype),
        "mlp": mlp_mod.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_type,
                                dtype),
    }


def _block_logical_enc(cfg):
    return {
        "ln1": norm_logical(cfg.d_model, cfg.norm_type),
        "attn": attn_mod.attention_logical(cfg),
        "ln2": norm_logical(cfg.d_model, cfg.norm_type),
        "mlp": mlp_mod.mlp_logical(cfg.d_model, cfg.d_ff, cfg.mlp_type),
    }


def _block_logical_dec(cfg):
    return {
        "ln1": norm_logical(cfg.d_model, cfg.norm_type),
        "self_attn": attn_mod.attention_logical(cfg),
        "ln_x": norm_logical(cfg.d_model, cfg.norm_type),
        "cross_attn": attn_mod.attention_logical(cfg),
        "ln2": norm_logical(cfg.d_model, cfg.norm_type),
        "mlp": mlp_mod.mlp_logical(cfg.d_model, cfg.d_ff, cfg.mlp_type),
    }


@dataclass
class EncDec:
    cfg: ModelConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(key, 4)
        enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
        dec_keys = jax.random.split(ks[1], cfg.num_layers)
        return {
            "frontend": init_frontend(ks[2], cfg, dtype),
            "embedding": init_embedding(ks[3], cfg, dtype),
            "enc_blocks": common.stack_params(
                [_init_enc_block(k, cfg, dtype) for k in enc_keys]),
            "enc_norm": init_norm(cfg.d_model, cfg.norm_type, dtype),
            "dec_blocks": common.stack_params(
                [_init_dec_block(k, cfg, dtype) for k in dec_keys]),
            "final_norm": init_norm(cfg.d_model, cfg.norm_type, dtype),
        }

    def logical(self) -> dict:
        cfg = self.cfg
        return {
            "frontend": frontend_logical(cfg),
            "embedding": embedding_logical(cfg),
            "enc_blocks": common.stack_logical(_block_logical_enc(cfg)),
            "enc_norm": norm_logical(cfg.d_model, cfg.norm_type),
            "dec_blocks": common.stack_logical(_block_logical_dec(cfg)),
            "final_norm": norm_logical(cfg.d_model, cfg.norm_type),
        }

    # ------------------------------------------------------------------
    def _maybe_remat(self, fn):
        if self.parallel.remat == "full":
            return jax.checkpoint(fn)
        if self.parallel.remat == "selective":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.
                dots_with_no_batch_dims_saveable)
        return fn

    def encode(self, params, enc_embeds, *, impl=None):
        cfg = self.cfg
        x = apply_frontend(params["frontend"], enc_embeds.astype(
            jnp.dtype(cfg.dtype)), cfg)
        b, s = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))

        def block(x, p):
            h = apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
            x = x + attn_mod.apply_attention(
                p["attn"], h, cfg, positions=pos, causal=False, impl=impl)
            h = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
            x = x + mlp_mod.apply_mlp(p["mlp"], h, cfg.mlp_type)
            return constrain(x, "batch", "seq", None)

        block = self._maybe_remat(block)
        x, _ = jax.lax.scan(lambda c, p: (block(c, p), None), x,
                            params["enc_blocks"])
        return apply_norm(params["enc_norm"], x, cfg.norm_type, cfg.norm_eps)

    def decode_states(self, params, tokens, enc_out, *, impl=None):
        cfg = self.cfg
        x = embed_tokens(params["embedding"], tokens, cfg)
        b, s = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = x + sinusoid(pos, cfg.d_model).astype(x.dtype)

        def block(x, p):
            h = apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
            x = x + attn_mod.apply_attention(
                p["self_attn"], h, cfg, positions=pos, impl=impl)
            h = apply_norm(p["ln_x"], x, cfg.norm_type, cfg.norm_eps)
            ek, ev = attn_mod.project_cross_kv(p["cross_attn"], enc_out, cfg)
            x = x + attn_mod.apply_cross_attention(
                p["cross_attn"], h, ek, ev, cfg, impl=impl)
            h = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
            x = x + mlp_mod.apply_mlp(p["mlp"], h, cfg.mlp_type)
            return constrain(x, "batch", "seq", None)

        block = self._maybe_remat(block)
        x, _ = jax.lax.scan(lambda c, p: (block(c, p), None), x,
                            params["dec_blocks"])
        return apply_norm(params["final_norm"], x, cfg.norm_type,
                          cfg.norm_eps)

    def apply(self, params, enc_embeds, dec_tokens, *, impl=None):
        enc_out = self.encode(params, enc_embeds, impl=impl)
        x = self.decode_states(params, dec_tokens, enc_out, impl=impl)
        return lm_logits(params["embedding"], x, self.cfg)

    def loss(self, params, enc_embeds, dec_tokens, labels, *, impl=None):
        logits = self.apply(params, enc_embeds, dec_tokens,
                            impl=impl).astype(jnp.float32)
        mask = labels >= 0
        lab = jnp.maximum(labels, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1)

    # ------------------------------------------------------------------
    # serving: self-attn KV cache + precomputed cross KV per layer
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, *, enc_out=None,
                   params=None):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        L = cfg.num_layers
        self_kv = common.stack_params(
            [attn_mod.init_kv_cache(cfg, batch, max_seq, dtype)
             for _ in range(L)])
        if enc_out is not None:
            def per_layer(p):
                return attn_mod.project_cross_kv(p["cross_attn"], enc_out,
                                                 cfg)
            cross = jax.vmap(per_layer)(params["dec_blocks"]) \
                if False else common.stack_params(
                [attn_mod.project_cross_kv(
                    jax.tree.map(lambda a: a[i],
                                 params["dec_blocks"])["cross_attn"],
                    enc_out, cfg) for i in range(L)])
        else:
            es = cfg.encoder_seq
            z = jnp.zeros((L, batch, es, cfg.num_kv_heads, cfg.head_dim),
                          dtype)
            cross = (z, z)
        return {"self": self_kv, "cross": cross}

    def cache_logical(self, batch: int, max_seq: int):
        cfg = self.cfg
        L = cfg.num_layers
        kvshape = (L, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
        crshape = (L, batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
        kv_ax = ("layers", "batch", "kv_seq", "heads", None)
        cr_ax = ("layers", "batch", None, "heads", None)
        from repro.layers.attention import KVCache
        return {"self": KVCache(k=(kv_ax, kvshape), v=(kv_ax, kvshape)),
                "cross": ((cr_ax, crshape), (cr_ax, crshape))}

    def decode_step(self, params, token, cache, pos, *, impl=None):
        cfg = self.cfg
        x = embed_tokens(params["embedding"], token[:, None], cfg)
        b = x.shape[0]
        posv = jnp.full((b, 1), pos, jnp.int32)
        x = x + sinusoid(posv, cfg.d_model).astype(x.dtype)

        def body(x, pc):
            p, kv, ck, cv = pc
            h = apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
            a, kv = attn_mod.apply_attention_decode(
                p["self_attn"], h, cfg, kv, pos=pos, impl=impl)
            x = x + a
            h = apply_norm(p["ln_x"], x, cfg.norm_type, cfg.norm_eps)
            x = x + attn_mod.apply_cross_attention(
                p["cross_attn"], h, ck, cv, cfg, impl=impl)
            h = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
            x = x + mlp_mod.apply_mlp(p["mlp"], h, cfg.mlp_type)
            return x, kv

        x, new_kv = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["self"],
                      cache["cross"][0], cache["cross"][1]))
        x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        logits = lm_logits(params["embedding"], x, cfg)
        return logits[:, 0], {"self": new_kv, "cross": cache["cross"]}
