"""Model factory: config -> model object (LM or EncDec)."""
from __future__ import annotations

from repro.config import ModelConfig, ParallelConfig


def build_model(cfg: ModelConfig, parallel: ParallelConfig = None):
    parallel = parallel or ParallelConfig()
    if cfg.is_encoder_decoder:
        from repro.models.encdec import EncDec
        return EncDec(cfg, parallel)
    from repro.models.lm import LM
    return LM(cfg, parallel)
