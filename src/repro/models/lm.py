"""Decoder-only language model covering the dense / moe / ssm / hybrid /
vlm families.

The per-layer block pattern (config.blocks()) is compressed into *segments*
of consecutive identical kinds; each multi-block segment is executed with
``lax.scan`` over layer-stacked parameters (small HLO, fast compiles at 512
devices) with configurable rematerialization.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig
from repro.layers import common
from repro.layers.embedding import (embed_tokens, embedding_logical,
                                    init_embedding, lm_logits)
from repro.layers.norms import apply_norm, init_norm, norm_logical
from repro.models import blocks as B
from repro.sharding.rules import constrain


def segments(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """Compress the block pattern into (kind, count) runs."""
    segs: List[Tuple[str, int]] = []
    for kind in cfg.blocks():
        if segs and segs[-1][0] == kind:
            segs[-1] = (kind, segs[-1][1] + 1)
        else:
            segs.append((kind, 1))
    return segs


def periodic_segments(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    """Detect a repeating *unit* (e.g. gemma2's (local, global)) so that
    alternating patterns still scan.  Returns [(unit_kinds, repeats)]."""
    blocks = cfg.blocks()
    n = len(blocks)
    for p in (1, 2, 3, 4):
        if n % p == 0 and len(set(blocks[i::p][0] for i in range(p))) >= 0:
            unit = blocks[:p]
            if all(blocks[i] == unit[i % p] for i in range(n)):
                return [(tuple(unit), n // p)]
    # fall back to plain runs
    return [((k,), c) for k, c in segments(cfg)]


@dataclass
class LM:
    cfg: ModelConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)

    # ------------------------------------------------------------------
    @property
    def segs(self):
        if self.parallel.scan_layers:
            return periodic_segments(self.cfg)
        return [((k,), 1) for k in self.cfg.blocks()]

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        keys = jax.random.split(key, len(self.segs) + 2)
        params: dict = {"embedding": init_embedding(keys[0], cfg, dtype),
                        "final_norm": init_norm(cfg.d_model, cfg.norm_type,
                                                dtype)}
        if cfg.modality != "text":
            from repro.layers.frontend import init_frontend
            params["frontend"] = init_frontend(keys[1], cfg, dtype)
        for si, (unit, reps) in enumerate(self.segs):
            kseg = jax.random.split(keys[si + 2], reps)

            def init_unit(k):
                ku = jax.random.split(k, len(unit))
                return {f"u{i}": B.init_block(ku[i], cfg, unit[i], dtype)
                        for i in range(len(unit))}

            if reps == 1:
                params[f"seg{si}"] = init_unit(kseg[0])
            else:
                params[f"seg{si}"] = common.stack_params(
                    [init_unit(k) for k in kseg])
        return params

    def logical(self) -> dict:
        cfg = self.cfg
        tree: dict = {"embedding": embedding_logical(cfg),
                      "final_norm": norm_logical(cfg.d_model, cfg.norm_type)}
        if cfg.modality != "text":
            from repro.layers.frontend import frontend_logical
            tree["frontend"] = frontend_logical(cfg)
        for si, (unit, reps) in enumerate(self.segs):
            unit_tree = {f"u{i}": B.block_logical(cfg, unit[i])
                         for i in range(len(unit))}
            if reps > 1:
                unit_tree = common.stack_logical(unit_tree)
            tree[f"seg{si}"] = unit_tree
        return tree

    # ------------------------------------------------------------------
    def _unit_fn(self, unit, *, positions, impl=None):
        cfg = self.cfg

        def run(x, unit_params):
            for i, kind in enumerate(unit):
                x = B.apply_block(unit_params[f"u{i}"], x, cfg, kind,
                                  positions=positions, impl=impl)
            return x

        if self.parallel.remat == "full":
            run = jax.checkpoint(run)
        elif self.parallel.remat == "selective":
            run = jax.checkpoint(
                run, policy=jax.checkpoint_policies.
                dots_with_no_batch_dims_saveable)
        return run

    def hidden_states(self, params, x, *, positions, impl=None):
        """Backbone forward: embedded input -> final-norm hidden states."""
        for si, (unit, reps) in enumerate(self.segs):
            run = self._unit_fn(unit, positions=positions, impl=impl)
            p = params[f"seg{si}"]
            if reps == 1:
                x = run(x, p)
            else:
                x, _ = jax.lax.scan(
                    lambda c, pp: (run(c, pp), None), x, p)
        return apply_norm(params["final_norm"], x, self.cfg.norm_type,
                          self.cfg.norm_eps)

    def apply(self, params, tokens=None, *, inputs_embeds=None,
              positions=None, impl=None):
        """Forward to logits.  tokens: (B, S) int32 or inputs_embeds
        (B, S, D) for the vlm/audio stubs."""
        cfg = self.cfg
        if inputs_embeds is not None:
            x = inputs_embeds.astype(jnp.dtype(cfg.dtype))
            if "frontend" in params:
                from repro.layers.frontend import apply_frontend
                x = apply_frontend(params["frontend"], x, cfg)
        else:
            x = embed_tokens(params["embedding"], tokens, cfg)
        if positions is None:
            b, s = x.shape[:2]
            pos2d = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                     (b, s))
            positions = (jnp.broadcast_to(pos2d, (3, b, s))
                         if cfg.rope_type == "mrope" else pos2d)
        x = self.hidden_states(params, x, positions=positions, impl=impl)
        return lm_logits(params["embedding"] if cfg.tie_embeddings
                         else params["embedding"], x, cfg)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        cache = {}
        for si, (unit, reps) in enumerate(self.segs):
            def unit_cache():
                return {f"u{i}": B.init_block_cache(cfg, unit[i], batch,
                                                    max_seq, dtype)
                        for i in range(len(unit))}
            if reps == 1:
                cache[f"seg{si}"] = unit_cache()
            else:
                cache[f"seg{si}"] = common.stack_params(
                    [unit_cache() for _ in range(reps)])
        return cache

    def init_paged_cache(self, num_pages: int, page_size: int):
        """Paged-serving cache: per-layer KV page pools (no batch dim --
        serving/paged_cache.PagedKVCache owns the page table that carves
        the pools into per-sequence caches)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        cache = {}
        for si, (unit, reps) in enumerate(self.segs):
            def unit_cache():
                return {f"u{i}": B.init_block_pages(
                            cfg, unit[i], num_pages, page_size, dtype)
                        for i in range(len(unit))}
            if reps == 1:
                cache[f"seg{si}"] = unit_cache()
            else:
                cache[f"seg{si}"] = common.stack_params(
                    [unit_cache() for _ in range(reps)])
        return cache

    def paged_cache_sharding(self, mesh, num_pages: int, page_size: int):
        """NamedSharding tree for ``init_paged_cache`` under the paged-TP
        mesh: pool leaves (Hkv, P, ps, D) shard kv heads over the
        head-group axis and within-page rows over the page-row axis
        (logical axes ``kv_heads`` / ``page_row`` in sharding/rules.py);
        scan-stacked segments carry a leading replicated reps dim.  Axes
        whose dimension does not divide fall back to replicated, so SSM
        state leaves (if any) stay whole."""
        from jax.sharding import NamedSharding
        from repro.sharding.rules import (default_rules, logical_to_spec,
                                          _divides)
        rules = dict(default_rules())
        shapes = jax.eval_shape(
            lambda: self.init_paged_cache(num_pages, page_size))

        def to_sharding(leaf):
            logical = ("kv_heads", None, "page_row", None)
            if leaf.ndim == 5:          # stacked segment: leading reps dim
                logical = (None,) + logical
            elif leaf.ndim != 4:
                return NamedSharding(mesh, jax.sharding.PartitionSpec())
            spec = logical_to_spec(logical, rules, mesh)
            fixed = [ax if _divides(leaf.shape[i], mesh, ax) else None
                     for i, ax in enumerate(spec)]
            return NamedSharding(mesh,
                                 jax.sharding.PartitionSpec(*fixed))

        return jax.tree.map(to_sharding, shapes)

    def cache_logical(self, batch: int, max_seq: int):
        cfg = self.cfg
        tree = {}
        for si, (unit, reps) in enumerate(self.segs):
            unit_tree = {f"u{i}": B.block_cache_logical(cfg, unit[i], batch,
                                                        max_seq)
                         for i in range(len(unit))}
            if reps > 1:
                unit_tree = common.stack_logical(unit_tree)
            tree[f"seg{si}"] = unit_tree
        return tree

    def _cached_segments(self, params, x, cache, block_fn):
        """Shared cached-forward skeleton: thread (x, cache) through every
        segment (scanning stacked units), final-norm and project to
        logits.  ``block_fn(block_params, x, kind, block_cache) ->
        (x, new_block_cache)`` supplies the per-block forward (one-token
        decode or a whole prefill chunk, dense or paged caches).
        x: (B, S, D) embedded input; returns (logits (B, S, V), cache)."""
        cfg = self.cfg
        new_cache = {}
        for si, (unit, reps) in enumerate(self.segs):

            def run(x, unit_params, unit_cache):
                ncache = {}
                for i, kind in enumerate(unit):
                    x, c = block_fn(unit_params[f"u{i}"], x, kind,
                                    unit_cache[f"u{i}"])
                    ncache[f"u{i}"] = c
                return x, ncache

            p, c = params[f"seg{si}"], cache[f"seg{si}"]
            if reps == 1:
                x, nc = run(x, p, c)
            else:
                def body(carry, pc):
                    pp, cc = pc
                    y, nc = run(carry, pp, cc)
                    return y, nc
                x, nc = jax.lax.scan(body, x, (p, c))
            new_cache[f"seg{si}"] = nc
        x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        logits = lm_logits(params["embedding"], x, cfg)
        return logits, new_cache

    def decode_step(self, params, token, cache, pos, *, impl=None):
        """token: (B,) int32; pos: scalar int32.  Returns (logits, cache)."""
        def block_fn(bp, x, kind, bc):
            return B.apply_block_decode(bp, x, self.cfg, kind, bc, pos=pos,
                                        impl=impl)
        x = embed_tokens(params["embedding"], token[:, None], self.cfg)
        logits, cache = self._cached_segments(params, x, cache, block_fn)
        return logits[:, 0], cache

    def decode_step_paged(self, params, token, cache, page_table, pos, *,
                          impl=None):
        """Paged decode step.  token: (B,) int32; pos: (B,) int32
        per-sequence positions (ragged batch); page_table: (B, n_kv)
        int32.  Returns (logits, cache) with cache = the page pools."""
        def block_fn(bp, x, kind, bc):
            return B.apply_block_decode_paged(
                bp, x, self.cfg, kind, bc, page_table=page_table, pos=pos,
                impl=impl)
        x = embed_tokens(params["embedding"], token[:, None], self.cfg)
        logits, cache = self._cached_segments(params, x, cache, block_fn)
        return logits[:, 0], cache

    def prefill_chunk_paged(self, params, tokens, cache, page_table,
                            pos_start, n_valid, *, impl=None):
        """Chunked paged prefill: one fixed-size prompt chunk through the
        full transformer forward, writing K/V into the paged pools.

        tokens: (B, C) int32 chunk (padded past ``n_valid``); page_table:
        (B, n_kv) int32; pos_start / n_valid: (B,) int32 runtime offsets
        -- jit traces are keyed by the chunk size C, never by prompt
        length or chunk position.  Returns (logits (B, C, V), cache);
        logit rows past ``n_valid`` are garbage (their K/V went to the
        scratch page).
        """
        def block_fn(bp, x, kind, bc):
            return B.apply_block_prefill_paged(
                bp, x, self.cfg, kind, bc, page_table=page_table,
                pos_start=pos_start, n_valid=n_valid, impl=impl)
        x = embed_tokens(params["embedding"], tokens, self.cfg)
        return self._cached_segments(params, x, cache, block_fn)

    # ------------------------------------------------------------------
    def loss(self, params, tokens, labels, *, impl=None):
        """Mean next-token cross entropy; labels < 0 are masked."""
        logits = self.apply(params, tokens, impl=impl).astype(jnp.float32)
        mask = labels >= 0
        lab = jnp.maximum(labels, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
